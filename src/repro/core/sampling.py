"""Random sampling of strings from a Grammar.

Used by (a) the tokenizer-training corpus generator, (b) the synthetic data
pipeline for training the in-repo models on grammar-structured text, and
(c) property-based tests (every sampled string must be accepted by DOMINO).
"""
from __future__ import annotations

import random
from typing import List, Optional

from repro.core import regex as rx
from repro.core.grammar import Grammar, is_terminal, nt_id


def sample_from_dfa(dfa: rx.DFA, rng: random.Random,
                    max_len: int = 12) -> bytes:
    """Random accepted string of the DFA (biased toward short strings).

    All DFA states are live, so a path to acceptance always exists; we stop
    at accepting states with increasing probability.
    """
    out = bytearray()
    state = dfa.start
    while True:
        accept = dfa.is_accept(state)
        cont = dfa.can_continue(state)
        if accept and (not cont or len(out) >= max_len
                       or rng.random() < 0.35):
            return bytes(out)
        if not cont:
            return bytes(out)  # accept must hold (live states)
        # prefer printable bytes when available, for readable corpora
        choices = list(dfa.trans[state].keys())
        printable = [b for b in choices if 32 <= b < 127]
        b = rng.choice(printable or choices)
        out.append(b)
        state = dfa.step(state, b)


class GrammarSampler:
    def __init__(self, grammar: Grammar, seed: int = 0,
                 max_depth: int = 24, ws: bytes = b" "):
        self.g = grammar
        self.rng = random.Random(seed)
        self.max_depth = max_depth
        self.ws = ws
        # minimal expansion depth per nonterminal, to steer away from
        # divergence when the depth budget runs low
        self.min_depth = self._min_depths()

    def _min_depths(self):
        INF = 1 << 30
        depth = {n: INF for n in range(self.g.n_nonterminals)}
        changed = True
        while changed:
            changed = False
            for r in self.g.rules:
                d = 0
                for s in r.rhs:
                    if is_terminal(s):
                        continue
                    d = max(d, depth[nt_id(s)])
                d = d + 1 if d < INF else INF
                if d < depth[r.lhs]:
                    depth[r.lhs] = d
                    changed = True
        return depth

    def sample(self, max_ws: float = 0.15) -> bytes:
        """One random sentence; ``max_ws`` = chance of inserting whitespace
        between adjacent terminals (exercises the ignore channel)."""
        parts: List[bytes] = []
        self._expand(self.g.start, 0, parts)
        joined = bytearray()
        ig = bool(self.g.ignore)

        def wordish(b: int) -> bool:
            return (48 <= b <= 57) or (65 <= b <= 90) or (97 <= b <= 122) \
                or b in (95, 46, 45)  # _ . -

        for i, p in enumerate(parts):
            if not p:
                continue
            if i and ig and joined:
                # mandatory separator when gluing would re-lex (keyword+ident,
                # number+number, ...); optional elsewhere
                if (wordish(joined[-1]) and wordish(p[0])) \
                        or self.rng.random() < max_ws:
                    joined += self.ws
            joined += p
        return bytes(joined)

    def _expand(self, n: int, depth: int, parts: List[bytes]) -> None:
        rules = self.g.rules_by_lhs.get(n, [])
        if depth >= self.max_depth:
            best = min(rules, key=lambda ri: self._rule_depth(ri))
            choice = best
        else:
            choice = self.rng.choice(rules)
        for s in self.g.rules[choice].rhs:
            if is_terminal(s):
                t = self.g.terminals[s]
                if t.is_literal:
                    parts.append(t.pattern.encode("utf-8"))
                else:
                    parts.append(sample_from_dfa(t.dfa, self.rng))
            else:
                self._expand(nt_id(s), depth + 1, parts)

    def _rule_depth(self, ri: int) -> int:
        d = 0
        for s in self.g.rules[ri].rhs:
            if not is_terminal(s):
                d = max(d, self.min_depth[nt_id(s)])
        return d

    def corpus(self, n: int, sep: bytes = b"\n") -> bytes:
        return sep.join(self.sample() for _ in range(n))
