"""Packed ``uint32`` token-mask bitsets (the mask-pipeline wire format).

A vocabulary mask is ``ceil(V/32)`` little words: bit ``b`` of word ``w``
(LSB first) is token ``w*32 + b``.  The same layout is consumed, unchanged,
by every stage of the pipeline:

 - tree build packs each node's token-id lists into per-node segments
   (``core/trees.py``), so mask assembly is a vectorized ``bitwise_or``
   over visited nodes instead of per-token fancy-index scatters;
 - the scheduler stages per-slot rows into a persistent ``(B, W)`` uint32
   buffer and ships THAT to the device — V/8 bytes per row instead of the
   V int8 bytes of the old dense staging array;
 - the fused sampling kernel (``kernels/masked_sample``) loads the words
   and unpacks them in-register, fused with the running argmax.

Packing is arithmetic (shift + sum), not ``np.packbits``-with-a-view, so
the layout is endianness-independent and matches the kernel's
``(word >> (lane % 32)) & 1`` unpack exactly.
"""
from __future__ import annotations

from typing import Iterable

import numpy as np

WORD_BITS = 32
_SHIFTS = np.arange(WORD_BITS, dtype=np.uint32)


def n_words(v: int) -> int:
    """Words per packed mask row for a vocabulary of ``v`` tokens."""
    return (v + WORD_BITS - 1) // WORD_BITS


def pack_bool(mask: np.ndarray) -> np.ndarray:
    """Bool/int (..., V) mask -> packed (..., ceil(V/32)) uint32.

    Bits past V in the final word are 0 (required by the kernel's tail
    tile contract).
    """
    mask = np.asarray(mask)
    v = mask.shape[-1]
    w = n_words(v)
    padded = np.zeros(mask.shape[:-1] + (w * WORD_BITS,), np.uint32)
    padded[..., :v] = mask.astype(bool)
    grouped = padded.reshape(mask.shape[:-1] + (w, WORD_BITS))
    return (grouped << _SHIFTS).sum(axis=-1, dtype=np.uint32)


def pack_ids(ids: Iterable[int], v: int) -> np.ndarray:
    """Token-id list -> packed (ceil(V/32),) uint32 segment."""
    out = np.zeros(n_words(v), np.uint32)
    ids = np.asarray(list(ids), np.int64)
    if ids.size:
        # bitwise_or.at: duplicate words in the index must accumulate
        np.bitwise_or.at(out, ids >> 5,
                         np.uint32(1) << (ids & 31).astype(np.uint32))
    return out


def set_bit(words: np.ndarray, tok: int) -> None:
    """Set one token's bit in a packed row, in place."""
    words[tok >> 5] |= np.uint32(1) << np.uint32(tok & 31)


def get_bit(words: np.ndarray, tok: int) -> bool:
    """Test one token's bit in a packed row."""
    return bool((words[tok >> 5] >> np.uint32(tok & 31)) & np.uint32(1))


def to_ids(words: np.ndarray, v: int) -> np.ndarray:
    """Packed (W,) uint32 row -> ascending token ids of the set bits.

    Only nonzero words are expanded, so sparse masks (the common grammar
    case) cost O(set words * 32), not O(V).
    """
    idx = np.nonzero(words)[0]
    if idx.size == 0:
        return np.empty(0, np.int64)
    bits = (words[idx, None] >> _SHIFTS) & np.uint32(1)
    r, c = np.nonzero(bits)            # row-major: ascending token order
    ids = (idx[r].astype(np.int64) << 5) + c
    return ids[ids < v]


def unpack(words: np.ndarray, v: int) -> np.ndarray:
    """Packed (..., W) uint32 -> bool (..., v)."""
    words = np.asarray(words, np.uint32)
    bits = (words[..., :, None] >> _SHIFTS) & np.uint32(1)
    flat = bits.reshape(words.shape[:-1] + (words.shape[-1] * WORD_BITS,))
    return flat[..., :v].astype(bool)
