"""Regular-expression engine: pattern -> NFA (Thompson) -> DFA (subset).

DOMINO (§3.1-§3.2) builds character-level automata for every grammar
terminal.  We operate on **bytes** (0..255) so the automata compose directly
with a byte-level BPE vocabulary: a vocabulary token is a byte string and is
fed byte-by-byte through terminal automata.

Supported syntax (sufficient for all App. C grammars of the paper):
  literals, ``.``, escapes (``\\n \\t \\r \\\\ \\" \\/ \\xNN \\d \\w \\s``),
  character classes ``[a-z_]`` / ``[^"\\\\]``, alternation ``|``, grouping
  ``()``, quantifiers ``* + ?`` and ``{m}`` / ``{m,}`` / ``{m,n}``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Tuple

ALPHABET_SIZE = 256

# ---------------------------------------------------------------------------
# Pattern AST
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Node:
    pass


@dataclasses.dataclass(frozen=True)
class Chars(Node):
    """A single input byte drawn from ``byte_set``."""

    byte_set: FrozenSet[int]


@dataclasses.dataclass(frozen=True)
class Concat(Node):
    parts: Tuple[Node, ...]


@dataclasses.dataclass(frozen=True)
class Alt(Node):
    options: Tuple[Node, ...]


@dataclasses.dataclass(frozen=True)
class Repeat(Node):
    inner: Node
    min: int
    max: Optional[int]  # None = unbounded


EPSILON = Concat(())

_DIGITS = frozenset(range(ord("0"), ord("9") + 1))
_WORD = frozenset(
    list(range(ord("a"), ord("z") + 1))
    + list(range(ord("A"), ord("Z") + 1))
    + list(range(ord("0"), ord("9") + 1))
    + [ord("_")]
)
_SPACE = frozenset(map(ord, " \t\n\r\f\v"))
_ANY = frozenset(range(ALPHABET_SIZE))


class RegexSyntaxError(ValueError):
    pass


class _Parser:
    def __init__(self, pattern: str):
        # Work on the UTF-8 byte expansion so multi-byte literals behave.
        self.data = pattern
        self.pos = 0

    def peek(self) -> Optional[str]:
        return self.data[self.pos] if self.pos < len(self.data) else None

    def next(self) -> str:
        ch = self.peek()
        if ch is None:
            raise RegexSyntaxError(f"unexpected end of pattern: {self.data!r}")
        self.pos += 1
        return ch

    # alternation -> concat ('|' concat)*
    def parse(self) -> Node:
        node = self._alternation()
        if self.pos != len(self.data):
            raise RegexSyntaxError(
                f"trailing characters at {self.pos} in {self.data!r}"
            )
        return node

    def _alternation(self) -> Node:
        options = [self._concat()]
        while self.peek() == "|":
            self.next()
            options.append(self._concat())
        if len(options) == 1:
            return options[0]
        return Alt(tuple(options))

    def _concat(self) -> Node:
        parts: List[Node] = []
        while True:
            ch = self.peek()
            if ch is None or ch in "|)":
                break
            parts.append(self._repeat())
        if len(parts) == 1:
            return parts[0]
        return Concat(tuple(parts))

    def _repeat(self) -> Node:
        atom = self._atom()
        while True:
            ch = self.peek()
            if ch == "*":
                self.next()
                atom = Repeat(atom, 0, None)
            elif ch == "+":
                self.next()
                atom = Repeat(atom, 1, None)
            elif ch == "?":
                self.next()
                atom = Repeat(atom, 0, 1)
            elif ch == "{":
                save = self.pos
                self.next()
                spec = ""
                while self.peek() not in (None, "}"):
                    spec += self.next()
                if self.peek() != "}" or not _valid_brace(spec):
                    # Not a quantifier -- treat '{' as literal.
                    self.pos = save
                    break
                self.next()
                lo, hi = _parse_brace(spec)
                atom = Repeat(atom, lo, hi)
            else:
                break
        return atom

    def _atom(self) -> Node:
        ch = self.next()
        if ch == "(":
            inner = self._alternation()
            if self.peek() != ")":
                raise RegexSyntaxError(f"unbalanced '(' in {self.data!r}")
            self.next()
            return inner
        if ch == "[":
            return self._char_class()
        if ch == ".":
            return Chars(_ANY)
        if ch == "\\":
            return Chars(self._escape())
        if ch in "*+?":
            raise RegexSyntaxError(f"dangling quantifier in {self.data!r}")
        bs = ch.encode("utf-8")
        if len(bs) > 1:  # multi-byte literal = byte sequence
            return Concat(tuple(Chars(frozenset([b])) for b in bs))
        return Chars(frozenset([bs[0]]))

    def _escape(self) -> FrozenSet[int]:
        ch = self.next()
        simple = {"n": "\n", "t": "\t", "r": "\r", "f": "\f", "v": "\v",
                  "0": "\0", "a": "\a", "b": "\b"}
        if ch in simple:
            return frozenset([ord(simple[ch])])
        if ch == "d":
            return _DIGITS
        if ch == "D":
            return _ANY - _DIGITS
        if ch == "w":
            return _WORD
        if ch == "W":
            return _ANY - _WORD
        if ch == "s":
            return _SPACE
        if ch == "S":
            return _ANY - _SPACE
        if ch == "x":
            hi, lo = self.next(), self.next()
            return frozenset([int(hi + lo, 16)])
        # Escaped literal metacharacter (\\, \", \/, \[, \. ...)
        return _char_bytes(ch)

    def _char_class(self) -> Node:
        negate = False
        if self.peek() == "^":
            self.next()
            negate = True
        members: set = set()
        first = True
        while True:
            ch = self.peek()
            if ch is None:
                raise RegexSyntaxError(f"unterminated class in {self.data!r}")
            if ch == "]" and not first:
                self.next()
                break
            first = False
            if ch == "\\":
                self.next()
                lo_set = self._escape()
                if len(lo_set) != 1:
                    members |= lo_set
                    continue
                lo = min(lo_set)
            else:
                self.next()
                bs = _char_bytes(ch)
                if len(bs) != 1:
                    # multi-byte utf-8 literal inside class: add all bytes
                    members |= bs
                    continue
                lo = min(bs)
            if self.peek() == "-" and self.pos + 1 < len(self.data) and self.data[self.pos + 1] != "]":
                self.next()  # consume '-'
                hc = self.next()
                if hc == "\\":
                    hi_set = self._escape()
                    hi = min(hi_set)
                else:
                    hi = min(_char_bytes(hc))
                members |= set(range(lo, hi + 1))
            else:
                members.add(lo)
        byte_set = frozenset(members)
        if negate:
            byte_set = _ANY - byte_set
        return Chars(byte_set)


def _char_bytes(ch: str) -> FrozenSet[int]:
    bs = ch.encode("utf-8")
    if len(bs) == 1:
        return frozenset([bs[0]])
    # A multi-byte character used as a single atom: represented downstream by
    # the caller via concat of its bytes. We signal with the full set here
    # and let parse() expand; simplest is to expand here:
    return frozenset(bs)  # handled in _atom for len>1 via Concat below


def _valid_brace(spec: str) -> bool:
    parts = spec.split(",")
    if len(parts) not in (1, 2):
        return False
    if not parts[0].isdigit():
        return False
    if len(parts) == 2 and parts[1] and not parts[1].isdigit():
        return False
    return True


def _parse_brace(spec: str) -> Tuple[int, Optional[int]]:
    parts = spec.split(",")
    lo = int(parts[0])
    if len(parts) == 1:
        return lo, lo
    return lo, (int(parts[1]) if parts[1] else None)


def parse(pattern: str) -> Node:
    """Parse ``pattern`` into an AST."""
    return _Parser(pattern).parse()


# ---------------------------------------------------------------------------
# Thompson construction -> NFA
# ---------------------------------------------------------------------------


class NFA:
    """Byte NFA with epsilon transitions.

    transitions[state] is a list of (byte_set | None, target); None = eps.
    """

    def __init__(self):
        self.transitions: List[List[Tuple[Optional[FrozenSet[int]], int]]] = []
        self.start = 0
        self.accepts: set = set()

    def new_state(self) -> int:
        self.transitions.append([])
        return len(self.transitions) - 1

    def add(self, src: int, label: Optional[FrozenSet[int]], dst: int) -> None:
        self.transitions[src].append((label, dst))

    @property
    def n_states(self) -> int:
        return len(self.transitions)


def _build(nfa: NFA, node: Node) -> Tuple[int, int]:
    """Return (entry, exit) fragment states for ``node``."""
    if isinstance(node, Chars):
        s, e = nfa.new_state(), nfa.new_state()
        # Multi-byte UTF-8 literal expanded as a byte chain when the set is a
        # contiguous utf-8 encoding; single bytes are the common case.
        nfa.add(s, node.byte_set, e)
        return s, e
    if isinstance(node, Concat):
        if not node.parts:
            s = nfa.new_state()
            return s, s
        entry, cur = None, None
        for part in node.parts:
            ps, pe = _build(nfa, part)
            if entry is None:
                entry = ps
            else:
                nfa.add(cur, None, ps)
            cur = pe
        return entry, cur
    if isinstance(node, Alt):
        s, e = nfa.new_state(), nfa.new_state()
        for opt in node.options:
            os_, oe = _build(nfa, opt)
            nfa.add(s, None, os_)
            nfa.add(oe, None, e)
        return s, e
    if isinstance(node, Repeat):
        lo, hi = node.min, node.max
        s = nfa.new_state()
        cur = s
        # mandatory copies
        for _ in range(lo):
            ps, pe = _build(nfa, node.inner)
            nfa.add(cur, None, ps)
            cur = pe
        if hi is None:
            # star/plus tail: loop
            ps, pe = _build(nfa, node.inner)
            loop_in = nfa.new_state()
            nfa.add(cur, None, loop_in)
            nfa.add(loop_in, None, ps)
            nfa.add(pe, None, loop_in)
            return s, loop_in
        # bounded optional copies
        end = nfa.new_state()
        nfa.add(cur, None, end)
        for _ in range(hi - lo):
            ps, pe = _build(nfa, node.inner)
            nfa.add(cur, None, ps)
            nfa.add(pe, None, end)
            cur = pe
        return s, end
    raise TypeError(node)


def to_nfa(node: Node) -> NFA:
    nfa = NFA()
    s, e = _build(nfa, node)
    nfa.start = s
    nfa.accepts = {e}
    return nfa


# ---------------------------------------------------------------------------
# Subset construction -> DFA
# ---------------------------------------------------------------------------


class DFA:
    """Deterministic byte automaton.

    ``trans[state]`` maps byte -> next state (sparse dict).
    ``accepts`` is a frozenset of accepting states.
    ``live`` marks states from which an accepting state is reachable; the
    subset construction only produces live states so every DFA state here is
    live by construction (dead sink omitted).
    """

    def __init__(self, trans: List[Dict[int, int]], start: int,
                 accepts: FrozenSet[int]):
        self.trans = trans
        self.start = start
        self.accepts = accepts

    @property
    def n_states(self) -> int:
        return len(self.trans)

    def step(self, state: int, byte: int) -> Optional[int]:
        return self.trans[state].get(byte)

    def is_accept(self, state: int) -> bool:
        return state in self.accepts

    def can_continue(self, state: int) -> bool:
        return bool(self.trans[state])

    def matches(self, data: bytes) -> bool:
        st: Optional[int] = self.start
        for b in data:
            st = self.step(st, b)
            if st is None:
                return False
        return st in self.accepts

    def first_bytes(self, state: int) -> FrozenSet[int]:
        return frozenset(self.trans[state].keys())


def _eps_closure(nfa: NFA, states: FrozenSet[int]) -> FrozenSet[int]:
    stack = list(states)
    seen = set(states)
    while stack:
        s = stack.pop()
        for label, dst in nfa.transitions[s]:
            if label is None and dst not in seen:
                seen.add(dst)
                stack.append(dst)
    return frozenset(seen)


def to_dfa(nfa: NFA) -> DFA:
    start_set = _eps_closure(nfa, frozenset([nfa.start]))
    index: Dict[FrozenSet[int], int] = {start_set: 0}
    order: List[FrozenSet[int]] = [start_set]
    trans: List[Dict[int, int]] = [{}]
    accepts: set = set()
    if nfa.accepts & start_set:
        accepts.add(0)
    i = 0
    while i < len(order):
        cur = order[i]
        # Gather outgoing byte moves.
        moves: Dict[int, set] = {}
        for s in cur:
            for label, dst in nfa.transitions[s]:
                if label is None:
                    continue
                for b in label:
                    moves.setdefault(b, set()).add(dst)
        for b, dsts in moves.items():
            nxt = _eps_closure(nfa, frozenset(dsts))
            if nxt not in index:
                index[nxt] = len(order)
                order.append(nxt)
                trans.append({})
                if nfa.accepts & nxt:
                    accepts.add(index[nxt])
            trans[i][b] = index[nxt]
        i += 1
    # Prune dead states (no path to accept) so can_continue() is meaningful.
    n = len(order)
    rev: List[set] = [set() for _ in range(n)]
    for s, m in enumerate(trans):
        for _, d in m.items():
            rev[d].add(s)
    live = set(accepts)
    stack = list(accepts)
    while stack:
        s = stack.pop()
        for p in rev[s]:
            if p not in live:
                live.add(p)
                stack.append(p)
    if 0 not in live:
        # Pattern matches nothing reachable; still return a 1-state dead DFA.
        return DFA([{}], 0, frozenset())
    remap = {}
    new_trans: List[Dict[int, int]] = []
    for s in range(n):
        if s in live:
            remap[s] = len(new_trans)
            new_trans.append({})
    for s in range(n):
        if s not in live:
            continue
        for b, d in trans[s].items():
            if d in live:
                new_trans[remap[s]][b] = remap[d]
    new_accepts = frozenset(remap[s] for s in accepts if s in live)
    return DFA(new_trans, remap[0], new_accepts)


def compile_pattern(pattern: str) -> DFA:
    """Compile a regex pattern string into a byte DFA."""
    return to_dfa(to_nfa(parse(pattern)))


def literal_dfa(text: str) -> DFA:
    """DFA matching exactly the UTF-8 bytes of ``text``."""
    data = text.encode("utf-8")
    trans: List[Dict[int, int]] = [{} for _ in range(len(data) + 1)]
    for i, b in enumerate(data):
        trans[i][b] = i + 1
    return DFA(trans, 0, frozenset([len(data)]))
