"""Vocabulary-aligned subterminal trees (Algorithm 2, §3.3).

For every scanner position ``q`` we enumerate, for **every** vocabulary
token, the subterminal sequences it induces, and organize them into a
prefix tree ``T_q`` keyed by the *parser-relevant* (non-ignorable) terminal
emissions.  Token ids are attached to the node reached by their emission
sequence, bucketed by how the token *ends*:

 - ``tokens_fresh``     — token ends exactly on a terminal boundary;
 - ``tokens_partial``   — token ends mid-terminal; bucketed by the frozenset
   of candidate terminal ids (the parser must accept at least one of them,
   or the terminal must be ignorable, for the token to be legal).

This is the precomputed data structure that makes DOMINO's mask computation
independent of vocabulary size: at inference time we walk ``T_q`` (pruned by
the parser, bounded by the lookahead ``k``) instead of scanning |V| tokens.

Construction shares work across tokens by DFS over a byte *trie* of the
vocabulary: all tokens with a common byte prefix reuse the same scanner
branch frontier.

Each node additionally carries *packed bitset segments* of its token
buckets (``fresh_bits`` / ``partial_bits``, uint32 words in the
``core/bitmask.py`` layout), attached once at build time.  Mask assembly
then becomes a vectorized ``np.bitwise_or`` accumulation over visited
nodes — no per-token-id fancy-index scatters on the serving critical
path — and the assembled full-vocabulary masks are memoized on the cache
(``mask_memo``), keyed by the decoder's immutable hypothesis state, so a
recurring grammar state is a dict lookup.
"""
from __future__ import annotations

import collections
import time
from typing import Dict, FrozenSet, Iterable, List, Optional, Tuple

import numpy as np

from repro.core import bitmask
from repro.core.scanner import FRESH, Scanner


class VocabTrie:
    """Byte trie over the vocabulary (token id -> byte string)."""

    __slots__ = ("children", "token_ids")

    def __init__(self):
        self.children: Dict[int, "VocabTrie"] = {}
        self.token_ids: List[int] = []

    @classmethod
    def build(cls, vocab: List[Optional[bytes]]) -> "VocabTrie":
        root = cls()
        for tok_id, data in enumerate(vocab):
            if data is None or len(data) == 0:
                continue  # special tokens (EOS/PAD) handled by the decoder
            node = root
            for b in data:
                nxt = node.children.get(b)
                if nxt is None:
                    nxt = cls()
                    node.children[b] = nxt
                node = nxt
            node.token_ids.append(tok_id)
        return root

    def count_nodes(self) -> int:
        n = 1
        for c in self.children.values():
            n += c.count_nodes()
        return n


class TreeNode:
    __slots__ = ("children", "tokens_fresh", "tokens_partial",
                 "fresh_bits", "partial_bits")

    def __init__(self):
        self.children: Dict[int, "TreeNode"] = {}
        self.tokens_fresh: List[int] = []
        # frozenset of candidate partial-terminal ids -> token ids
        self.tokens_partial: Dict[FrozenSet[int], List[int]] = {}
        # packed (ceil(V/32),) uint32 segments of the buckets above,
        # attached by TreeCache._build once construction is done; None
        # for an empty fresh bucket (the walk guards on the list)
        self.fresh_bits: Optional[np.ndarray] = None
        self.partial_bits: Dict[FrozenSet[int], np.ndarray] = {}

    def size(self) -> int:
        n = 1
        for c in self.children.values():
            n += c.size()
        return n

    def n_tokens(self) -> int:
        n = len(self.tokens_fresh) + sum(
            len(v) for v in self.tokens_partial.values())
        for c in self.children.values():
            n += c.n_tokens()
        return n


def _step_branches(scanner: Scanner, branches, byte: int):
    """Advance every (emissions -> configuration-set) branch by one byte."""
    starts = scanner.start_moves(byte)
    ignore = scanner.ignore
    new_branches: Dict[Tuple[int, ...], set] = {}
    for ems, confs in branches.items():
        direct = set()
        emit_terminals = set()
        for conf in confs:
            if conf == ("FRESH",):
                if starts:
                    direct.update(starts)
                continue
            t, s = conf
            dfa = scanner.dfas[t]
            s2 = dfa.step(s, byte)
            if s2 is not None:
                direct.add((t, s2))
            if dfa.is_accept(s):
                emit_terminals.add(t)
        if direct:
            new_branches.setdefault(ems, set()).update(direct)
        if starts:
            for t in emit_terminals:
                key = ems if t in ignore else ems + (t,)
                new_branches.setdefault(key, set()).update(starts)
    return new_branches


class SubterminalTree:
    def __init__(self, root: TreeNode, position):
        self.root = root
        self.position = position


class TreeCache:
    """Per-position subterminal trees with lazy construction + memoization.

    ``precompute()`` runs the offline pass of the paper: BFS over all scanner
    positions reachable through any vocabulary token, building every tree.
    """

    def __init__(self, scanner: Scanner, vocab: List[Optional[bytes]]):
        self.scanner = scanner
        self.vocab = vocab
        self.trie = VocabTrie.build(vocab)
        self.trees: Dict[object, SubterminalTree] = {}
        self.build_time_s = 0.0
        # full-mask memo, shared by every decoder on this grammar: key =
        # decoder hypothesis digest (DominoDecoder._memo_key) -> packed
        # (n_mask_words,) uint32 mask.  Entries never go STALE (grammar
        # states are immutable, a key maps to exactly one mask), but the
        # whole-history fingerprint in the key makes most decode steps a
        # fresh entry, so an uncapped memo grows without bound on a
        # long-lived server (n_mask_words*4 bytes per entry — 32 KiB at
        # gemma3's V).  LRU-evict past mask_memo_max (hits re-mark their
        # entry, so recurring grammar states survive churn that a FIFO
        # would evict them under): dropping an entry only costs a
        # rebuild, never correctness.
        self.n_mask_words = bitmask.n_words(len(vocab))
        self.mask_memo: "collections.OrderedDict[object, np.ndarray]" = \
            collections.OrderedDict()
        self.mask_memo_max = 4096
        # aggregate memo hits across EVERY decoder sharing this cache —
        # the cross-session mask-sharing signal (per-decoder counts live
        # on DominoDecoder.n_mask_memo_hits and die with the session)
        self.n_memo_hits = 0
        # device-resident decode table for this grammar (attached by
        # ServingEngine.build_device_tables when the closure certificate
        # is clean): a repro.core.analysis.DeviceGrammarTable, or None.
        # Kept on the cache so everything per-grammar that serving shares
        # lives in one object.
        self.device_table = None

    def tree(self, position) -> SubterminalTree:
        key = position
        t = self.trees.get(key)
        if t is None:
            t0 = time.perf_counter()
            t = self._build(position)
            self.build_time_s += time.perf_counter() - t0
            self.trees[key] = t
        return t

    def precompute(self) -> Dict[str, float]:
        """Offline pass: build trees for every reachable position.

        Returns stats (number of positions, total build seconds).
        """
        t0 = time.perf_counter()
        frontier = [FRESH]
        seen = {FRESH}
        while frontier:
            pos = frontier.pop()
            tree = self.tree(pos)
            for nxt in self._reachable_positions(tree):
                if nxt not in seen:
                    seen.add(nxt)
                    frontier.append(nxt)
        return {
            "positions": float(len(self.trees)),
            "seconds": time.perf_counter() - t0,
        }

    def reachable_positions(self, position) -> Iterable[object]:
        """Scanner positions reachable from ``position`` through ONE
        vocabulary token (recorded during tree construction).  Iterating
        this from FRESH to a fixpoint enumerates the whole scanner-side
        state space — ``precompute()`` does exactly that, and the static
        analyzer (:mod:`repro.core.analysis`) walks the same graph for
        its alignment-gap audit."""
        return self._reachable_positions(self.tree(position))

    def _reachable_positions(self, tree: SubterminalTree):
        # Positions are recorded during construction; see _build.
        return tree._positions  # type: ignore[attr-defined]

    def _build(self, position) -> SubterminalTree:
        scanner = self.scanner
        root = TreeNode()
        positions = set()

        def leaf_nodes(ems: Tuple[int, ...]) -> TreeNode:
            node = root
            for t in ems:
                nxt = node.children.get(t)
                if nxt is None:
                    nxt = TreeNode()
                    node.children[t] = nxt
                node = nxt
            return node

        def record(tok: int, branches) -> None:
            ignore = scanner.ignore
            seen_fresh = set()
            seen_partial = set()
            for ems, confs in branches.items():
                real = frozenset(c for c in confs if c != ("FRESH",))
                if real:
                    tids = frozenset(t for (t, _s) in real)
                    if (ems, tids) not in seen_partial:
                        seen_partial.add((ems, tids))
                        node = leaf_nodes(ems)
                        node.tokens_partial.setdefault(tids, []).append(tok)
                    positions.add(real)
                if ("FRESH",) in confs and ems not in seen_fresh:
                    seen_fresh.add(ems)
                    leaf_nodes(ems).tokens_fresh.append(tok)
                for (t, s) in real:
                    if scanner.dfas[t].is_accept(s):
                        key = ems if t in ignore else ems + (t,)
                        if key not in seen_fresh:
                            seen_fresh.add(key)
                            leaf_nodes(key).tokens_fresh.append(tok)
                            positions.add(FRESH)

        if position is FRESH:
            init = {(): {("FRESH",)}}
        else:
            init = {(): set(position)}

        def dfs(trie_node: VocabTrie, branches) -> None:
            for tok in trie_node.token_ids:
                record(tok, branches)
            for byte, child in trie_node.children.items():
                nb = _step_branches(scanner, branches, byte)
                if nb:
                    dfs(child, nb)

        dfs(self.trie, init)
        self._attach_bits(root)
        tree = SubterminalTree(root, position)
        tree._positions = positions  # type: ignore[attr-defined]
        return tree

    def _attach_bits(self, root: TreeNode) -> None:
        """Pack every node's token buckets into uint32 bitset segments
        (build-time cost, so the mask walk is pure bitwise_or)."""
        v = len(self.vocab)
        stack = [root]
        while stack:
            node = stack.pop()
            if node.tokens_fresh:
                node.fresh_bits = bitmask.pack_ids(node.tokens_fresh, v)
            node.partial_bits = {
                tids: bitmask.pack_ids(toks, v)
                for tids, toks in node.tokens_partial.items()}
            stack.extend(node.children.values())
