"""Grammar × vocabulary static analysis (registration-time verification).

The paper's central claim is that constrained decoding fails when grammars
and sub-word vocabularies are misaligned; until now this repo only
discovered such failures at runtime, as a per-request ``dead_end`` flag
after tokens were already burned.  This module proves (or refutes)
alignment *before* a grammar serves traffic, in two layers:

**Layer 1 — CFG/lexer alone** (:func:`analyze_static`): unreachable and
unproductive nonterminals, terminals whose regex denotes the empty
language, terminals whose whole language is swallowed by a scanner
``%ignore`` rule, and left-recursion / nullable-cycle hazards for the
Earley chart.  Pure symbol-level fixpoints + DFA product constructions —
no vocabulary involved.

**Layer 2 — grammar × vocabulary** (:func:`explore_decoder`): exhaustive
BFS over the reachable DOMINO decoder state space on the finite quotient
``DominoDecoder.abstract_key(clamp)`` = frozenset of per-hypothesis
(position-relative parser signature, scanner position).  Every abstract
state keeps a CONCRETE representative decoder (the first one to reach
it), so per-state packed masks come from the real PR-4 bitset walk and
every reported witness is a real token path.  The exploration yields:

 - **trap states** — reachable states whose packed mask is empty with
   EOS illegal (exactly the runtime ``dead_end`` condition, since
   ``mask_bits()`` bakes the EOS bit in).  Each carries its shortest
   concrete witness token path, replayed through a fresh
   ``DominoDecoder`` to confirm;
 - **EOS-liveness** — states from which no path reaches an EOS-legal
   state (reverse reachability over the recorded edges; only claimed
   when the closure is finite);
 - **alignment gaps** — terminals no vocabulary token sequence can
   spell (they appear in no subterminal-tree emission edge and no
   EOS-boundary emission), i.e. productions statically unreachable
   under this tokenizer;
 - a **closure certificate** — whether the quotient closed under the
   state bound, its state/edge count, and the implied device
   mask-table footprint (``states × ceil(V/32)`` uint32 words): the
   enumeration the ROADMAP's device-resident decode loop uploads.

Soundness of the quotient (READ THIS before trusting a verdict):
``rel_signature`` clamps chart origins, so two concrete decoder states
may share an abstract key while behaving differently beyond the clamp
horizon.  Consequences:

 - every reported trap is REAL (its witness is a concrete replayed
   path) — no false positives;
 - "trap-free" / "EOS-live" verdicts are certificates about the
   *representatives explored*: a conflated state could in principle
   hide a trap.  The explorer therefore samples merge consistency —
   when a transition lands on an already-known key, it periodically
   compares the arriving decoder's mask against the representative's
   (``n_mask_conflicts``).  Zero conflicts over all merges is strong
   evidence the quotient is exact for this grammar; any nonzero count
   downgrades the certificate and is reported as an error.

Policy (:func:`enforce`): ``off`` skips analysis entirely; ``warn``
reports problems as a ``RuntimeWarning`` and registers the grammar
anyway; ``strict`` raises :class:`AnalysisError` *before* the grammar is
registered.  ``warn`` therefore guarantees nothing beyond visibility;
``strict`` guarantees no registered grammar has a known trap, dead
terminal, unproductive reachable nonterminal, alignment gap, or
EOS-liveness hole (modulo the quotient caveat above, tempered by the
conflict sampler and witness replay).
"""
from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core import bitmask
from repro.core.domino import DominoDecoder
from repro.core.grammar import Grammar, is_terminal, nt_id
from repro.core.regex import DFA
from repro.core.scanner import FRESH, Scanner
from repro.core.trees import TreeCache

POLICIES = ("off", "warn", "strict")
DEFAULT_MAX_STATES = 2048
DEFAULT_CLAMP = 8
# transition-table sentinel: "this (state, token) edge leaves the
# precomputed frontier" — the serving scheduler falls the row back to the
# host path when its state id goes negative
OFF_FRONTIER = -1
# every Nth merge onto a known abstract state re-derives the mask and
# compares it against the representative's (quotient-soundness sampling)
MERGE_CHECK_STRIDE = 7


# ---------------------------------------------------------------------------
# report datatypes
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Issue:
    """One layer-1 finding (or an alignment gap)."""
    kind: str          # e.g. "empty-terminal", "unreachable-nonterminal"
    severity: str      # "error" | "warning" | "info"
    symbol: str        # terminal/nonterminal name
    detail: str

    def __str__(self) -> str:
        return f"[{self.severity}] {self.kind}: {self.symbol} — {self.detail}"


@dataclasses.dataclass
class Witness:
    """A concrete token path from the start state to an abstract state."""
    state_id: int
    token_ids: List[int]
    text: bytes              # the bytes the token path spells
    confirmed: bool          # fresh-decoder replay reproduced the verdict

    def __str__(self) -> str:
        return (f"state {self.state_id} via {self.token_ids} "
                f"({self.text!r}, {'confirmed' if self.confirmed else 'UNCONFIRMED'})")


@dataclasses.dataclass
class ClosureCertificate:
    """Finite-state-space certificate for the device-resident decode loop.

    When ``finite`` is True the explored graph IS the whole reachable
    quotient: ``n_states`` packed mask rows of ``mask_words`` uint32
    words each (``table_bytes`` on device) plus the recorded transition
    edges are sufficient to run decode without per-token host syncs.
    """
    finite: bool
    n_states: int
    n_edges: int
    mask_words: int          # ceil(V/32)
    table_words: int         # n_states * mask_words
    table_bytes: int         # table_words * 4
    clamp: int
    max_states: int          # the bound the exploration ran under


@dataclasses.dataclass
class DeviceGrammarTable:
    """Device-residency payload for one certified grammar.

    ``mask_table[sid]`` is state ``sid``'s packed legality bitset (the
    exact array ``DominoDecoder.mask_bits()`` returns in that state, EOS
    bit included) and ``trans[sid, tok]`` is the state reached by
    advancing ``tok`` — :data:`OFF_FRONTIER` for tokens the mask forbids
    and for EOS (an absorbing final state the loop checks explicitly).
    Uploaded once per grammar by ``ServingEngine.precompute()``; the
    scheduler's fused decode loop then gathers each row's mask from
    ``mask_table[state]`` and advances ``state = trans[state, tok]``
    entirely on device, syncing to the host only every N tokens.

    Only built from a CLEAN closure certificate (finite, zero merge
    conflicts, zero hypothesis truncations, zero trap states), so inside
    the table: every masked-argmax pick has a recorded transition, no
    reachable state has an empty mask, and the table mask is bitwise
    equal to the concrete checker's mask at the same state.

    Memory: ``n_states * ceil(V/32) * 4`` bytes of masks plus
    ``n_states * V * 4`` bytes of (dense int32) transitions.
    """
    n_states: int
    v: int                     # vocabulary size (table column count)
    eos_id: int
    clamp: int                 # abstract_key clamp the states are keyed by
    mask_table: np.ndarray     # (n_states, ceil(V/32)) uint32
    trans: np.ndarray          # (n_states, V) int32, OFF_FRONTIER sentinel
    key_to_sid: Dict[Tuple, int] = dataclasses.field(default_factory=dict,
                                                     repr=False)

    @property
    def n_bytes(self) -> int:
        return int(self.mask_table.nbytes + self.trans.nbytes)

    def sid_for(self, decoder) -> int:
        """State id of ``decoder``'s current abstract state, or
        :data:`OFF_FRONTIER` when the state is outside the table (the
        caller must then stay on / fall back to the host path)."""
        return self.key_to_sid.get(decoder.abstract_key(self.clamp),
                                   OFF_FRONTIER)


@dataclasses.dataclass
class AnalysisReport:
    grammar_name: str
    vocab_size: int
    eos_id: int
    n_terminals: int
    n_nonterminals: int
    n_rules: int
    issues: List[Issue]                  # layer 1
    alignment_gaps: List[Issue]          # layer 2 (kind="alignment-gap")
    traps: List[Witness]                 # layer 2
    non_eos_live: List[Witness]          # layer 2 (only when finite)
    closure: ClosureCertificate
    max_abstract_fanout: int             # max |hyps| over explored states
    n_merge_checks: int
    n_mask_conflicts: int                # quotient-soundness sampler
    # explored edges that overflowed the decoder's MAX_HYPOTHESES cap:
    # the grammar x vocabulary pair admits more viable token
    # segmentations than the runtime tracks, so runtime masks past such
    # an edge may silently exclude legal tokens.  Warning-level (the
    # grammar still serves), but the runtime counter
    # GenerationResult.n_hyp_truncations will fire on real traffic.
    n_hyp_truncations: int
    analysis_time_s: float
    # populated by analyze(..., emit_device_table=True) when — and only
    # when — the closure certificate is clean (finite, zero merge
    # conflicts, zero truncations, zero traps): the packed-mask +
    # transition tables the device-resident decode loop uploads
    device_table: Optional[DeviceGrammarTable] = None

    # -- verdicts ----------------------------------------------------------

    def problems(self) -> List[str]:
        """Everything that blocks ``strict`` registration."""
        out = [str(i) for i in self.issues if i.severity == "error"]
        out += [str(g) for g in self.alignment_gaps]
        out += [f"trap state: {w}" for w in self.traps]
        out += [f"not EOS-live: {w}" for w in self.non_eos_live]
        if self.n_mask_conflicts:
            out.append(
                f"quotient conflict: {self.n_mask_conflicts}/"
                f"{self.n_merge_checks} sampled merges disagreed on the "
                f"mask — the clamp={self.closure.clamp} abstraction "
                "conflates distinct states; raise clamp")
        return out

    def ok(self) -> bool:
        return not self.problems()

    def summary(self) -> str:
        c = self.closure
        lines = [
            f"grammar {self.grammar_name!r}: "
            f"{self.n_terminals} terminals, {self.n_nonterminals} "
            f"nonterminals, {self.n_rules} rules, |V|={self.vocab_size}",
            f"  closure: {'FINITE' if c.finite else 'NOT CLOSED'} under "
            f"{c.max_states} states (clamp={c.clamp}): {c.n_states} "
            f"states, {c.n_edges} edges; mask table "
            f"{c.n_states}x{c.mask_words} words = {c.table_bytes} bytes",
            f"  ambiguity: max hypothesis fan-out "
            f"{self.max_abstract_fanout}; merge checks "
            f"{self.n_merge_checks}, conflicts {self.n_mask_conflicts}",
        ]
        if self.n_hyp_truncations:
            lines.append(
                f"  [warning] hypothesis-truncation: "
                f"{self.n_hyp_truncations} explored edges overflowed "
                f"MAX_HYPOTHESES — runtime masks may be unsound on "
                f"highly ambiguous inputs (watch "
                f"GenerationResult.n_hyp_truncations)")
        for i in self.issues:
            lines.append(f"  {i}")
        for g in self.alignment_gaps:
            lines.append(f"  {g}")
        for w in self.traps:
            lines.append(f"  [error] trap: {w}")
        for w in self.non_eos_live:
            lines.append(f"  [error] not EOS-live: {w}")
        lines.append(
            f"  verdict: {'OK' if self.ok() else 'FAIL'} "
            f"({self.analysis_time_s:.2f}s)")
        return "\n".join(lines)

    def to_dict(self) -> dict:
        """JSON-safe dump (bytes witnesses become latin-1 strings)."""
        def wit(w: Witness) -> dict:
            return {"state_id": w.state_id, "token_ids": list(w.token_ids),
                    "text": w.text.decode("latin-1"),
                    "confirmed": w.confirmed}
        return {
            "grammar": self.grammar_name,
            "vocab_size": self.vocab_size,
            "eos_id": self.eos_id,
            "n_terminals": self.n_terminals,
            "n_nonterminals": self.n_nonterminals,
            "n_rules": self.n_rules,
            "issues": [dataclasses.asdict(i) for i in self.issues],
            "alignment_gaps": [dataclasses.asdict(g)
                               for g in self.alignment_gaps],
            "traps": [wit(w) for w in self.traps],
            "non_eos_live": [wit(w) for w in self.non_eos_live],
            "closure": dataclasses.asdict(self.closure),
            "max_abstract_fanout": self.max_abstract_fanout,
            "n_merge_checks": self.n_merge_checks,
            "n_mask_conflicts": self.n_mask_conflicts,
            "n_hyp_truncations": self.n_hyp_truncations,
            "analysis_time_s": self.analysis_time_s,
            "device_table": None if self.device_table is None else {
                "n_states": self.device_table.n_states,
                "v": self.device_table.v,
                "mask_bytes": int(self.device_table.mask_table.nbytes),
                "trans_bytes": int(self.device_table.trans.nbytes),
                "total_bytes": self.device_table.n_bytes,
            },
            "ok": self.ok(),
            "problems": self.problems(),
        }


class AnalysisError(ValueError):
    """Raised by :func:`enforce` under the ``strict`` policy."""

    def __init__(self, report: AnalysisReport, msg: str):
        super().__init__(msg)
        self.report = report


def enforce(report: AnalysisReport, policy: str) -> AnalysisReport:
    """Apply the registration policy to ``report``.

    ``off``: no-op.  ``warn``: problems become one RuntimeWarning.
    ``strict``: problems raise :class:`AnalysisError` (callers run this
    BEFORE registering, so a strict failure registers nothing).
    """
    if policy not in POLICIES:
        raise ValueError(f"analysis policy must be one of {POLICIES}, "
                         f"got {policy!r}")
    if policy == "off":
        return report
    problems = report.problems()
    if problems:
        msg = (f"grammar {report.grammar_name!r} failed static analysis "
               f"({len(problems)} problem(s)):\n  " + "\n  ".join(problems))
        if policy == "strict":
            raise AnalysisError(report, msg)
        warnings.warn(msg, RuntimeWarning, stacklevel=3)
    return report


# ---------------------------------------------------------------------------
# layer 1: CFG / lexer
# ---------------------------------------------------------------------------


def _dfa_minus_nonempty(a: DFA, b: DFA) -> bool:
    """Is ``L(a) \\ L(b)`` nonempty?  Product BFS where ``b`` may fall
    into its (pruned) dead sink, represented as None."""
    start = (a.start, b.start)
    seen = {start}
    stack = [start]
    while stack:
        sa, sb = stack.pop()
        if a.is_accept(sa) and (sb is None or not b.is_accept(sb)):
            return True
        for byte, na in a.trans[sa].items():
            nb = None if sb is None else b.trans[sb].get(byte)
            pair = (na, nb)
            if pair not in seen:
                seen.add(pair)
                stack.append(pair)
    return False


def dfa_subset(a: DFA, b: DFA) -> bool:
    """L(a) ⊆ L(b)."""
    return not _dfa_minus_nonempty(a, b)


def _cycle_nodes(edges: Dict[int, Set[int]]) -> Set[int]:
    """Nodes that lie on a directed cycle (node reaches itself)."""
    # transitive closure by per-node DFS; grammars are small
    on_cycle: Set[int] = set()
    for n0 in edges:
        stack = list(edges.get(n0, ()))
        seen: Set[int] = set()
        while stack:
            n = stack.pop()
            if n == n0:
                on_cycle.add(n0)
                break
            if n in seen:
                continue
            seen.add(n)
            stack.extend(edges.get(n, ()))
    return on_cycle


def reachable_nonterminals(g: Grammar) -> Tuple[Set[int], Set[int]]:
    """(reachable nonterminal ids, terminal ids referenced by a reachable
    rule)."""
    reach = {g.start}
    stack = [g.start]
    terms: Set[int] = set()
    while stack:
        n = stack.pop()
        for ri in g.rules_by_lhs.get(n, []):
            for s in g.rules[ri].rhs:
                if is_terminal(s):
                    terms.add(s)
                elif nt_id(s) not in reach:
                    reach.add(nt_id(s))
                    stack.append(nt_id(s))
    return reach, terms


def empty_terminals(g: Grammar) -> Set[int]:
    """Terminal ids whose regex denotes the EMPTY language (the compiled
    DFA has no accepting state — ``grammar.py`` rejects empty-*string*
    matchers at parse time but cannot see empty-*language* patterns)."""
    return {tid for tid, t in enumerate(g.terminals) if not t.dfa.accepts}


def productive_nonterminals(g: Grammar,
                            dead_terms: Optional[Set[int]] = None
                            ) -> Set[int]:
    """Nonterminals that derive at least one finite terminal string
    (terminals with an empty language count as underivable)."""
    dead = empty_terminals(g) if dead_terms is None else dead_terms
    prod: Set[int] = set()
    changed = True
    while changed:
        changed = False
        for r in g.rules:
            if r.lhs in prod:
                continue
            if all((s not in dead) if is_terminal(s) else (nt_id(s) in prod)
                   for s in r.rhs):
                prod.add(r.lhs)
                changed = True
    return prod


def analyze_static(g: Grammar) -> List[Issue]:
    """Layer 1: symbol-level verification of the CFG + lexer."""
    issues: List[Issue] = []
    dead = empty_terminals(g)
    reach, used_terms = reachable_nonterminals(g)
    prod = productive_nonterminals(g, dead)

    for tid in sorted(dead):
        if tid in used_terms or tid in g.ignore:
            issues.append(Issue(
                "empty-terminal", "error", g.terminal_name(tid),
                "regex denotes the empty language — no byte string can "
                "ever match; every production requiring it is a "
                "guaranteed trap"))
    for n in range(g.n_nonterminals):
        if n not in reach:
            issues.append(Issue(
                "unreachable-nonterminal", "warning",
                g.nonterminal_names[n],
                "never derivable from the start symbol (dead rules)"))
    for tid in range(g.n_terminals):
        if tid not in used_terms and tid not in g.ignore \
                and tid not in dead:
            issues.append(Issue(
                "unused-terminal", "warning", g.terminal_name(tid),
                "referenced by no reachable rule and not %ignore'd — the "
                "scanner still forks hypotheses on every match"))
    for n in sorted(reach):
        if n not in prod:
            issues.append(Issue(
                "unproductive-nonterminal", "error",
                g.nonterminal_names[n],
                "derives no finite terminal string; any decode entering "
                "it can never reach EOS"))

    # %ignore shadowing: a parser-visible terminal whose WHOLE language is
    # also skippable forks the hypothesis set on every occurrence (the
    # scanner keeps both the emit and the ignore branch).
    for tid in sorted(used_terms - dead):
        if tid in g.ignore:
            continue
        for iid in g.ignore:
            if iid in dead:
                continue
            if dfa_subset(g.terminals[tid].dfa, g.terminals[iid].dfa):
                issues.append(Issue(
                    "ignore-shadowed-terminal", "warning",
                    g.terminal_name(tid),
                    f"its whole language is also matched by %ignore "
                    f"terminal {g.terminal_name(iid)} — every occurrence "
                    "doubles the hypothesis fan-out (emit vs skip)"))
                break

    # Left recursion through nullable prefixes: A -> α B ... with α
    # nullable puts B at the leftmost derivation frontier of A.
    ledges: Dict[int, Set[int]] = {n: set() for n in range(g.n_nonterminals)}
    for r in g.rules:
        for s in r.rhs:
            if is_terminal(s):
                break
            ledges[r.lhs].add(nt_id(s))
            if nt_id(s) not in g.nullable:
                break
    for n in sorted(_cycle_nodes(ledges) & reach):
        issues.append(Issue(
            "left-recursion", "info", g.nonterminal_names[n],
            "left-recursive — Earley handles it, but chart item sets "
            "grow with nesting depth; the abstract closure may need a "
            "larger origin clamp to stay finite"))

    # Nullable cycles: A =>+ A consuming nothing — infinitely many
    # derivations of the empty string through A (ambiguity blow-up).
    nedges: Dict[int, Set[int]] = {n: set() for n in range(g.n_nonterminals)}
    for r in g.rules:
        if r.rhs and all((not is_terminal(s)) and nt_id(s) in g.nullable
                         for s in r.rhs):
            for s in r.rhs:
                nedges[r.lhs].add(nt_id(s))
    for n in sorted(_cycle_nodes(nedges) & reach):
        issues.append(Issue(
            "nullable-cycle", "warning", g.nonterminal_names[n],
            "derives itself while producing nothing — infinitely "
            "ambiguous epsilon derivations inflate Earley completion "
            "work at every position"))
    return issues


# ---------------------------------------------------------------------------
# layer 2: grammar x vocabulary
# ---------------------------------------------------------------------------


def spellable_terminals(g: Grammar, tc: TreeCache) -> Set[int]:
    """Terminal ids some vocabulary token SEQUENCE can emit to the
    parser: the union of subterminal-tree emission-edge labels over every
    reachable scanner position, plus EOS-boundary emissions.  Ignore
    terminals are excluded (their emissions are collapsed before the
    parser ever sees them)."""
    tc.precompute()                      # builds trees for all positions
    out: Set[int] = set()
    for pos, tree in tc.trees.items():
        stack = [tree.root]
        while stack:
            node = stack.pop()
            for t, child in node.children.items():
                out.add(t)
                stack.append(child)
        for ems, _clean in tc.scanner.final_branches(pos):
            out.update(ems)
    return out


def alignment_gap_issues(g: Grammar, tc: TreeCache,
                         vocab: Sequence[Optional[bytes]]) -> List[Issue]:
    """Terminals a reachable rule needs but NO token sequence of this
    vocabulary can spell (empty-language terminals are layer-1 findings
    and excluded here)."""
    _reach, used = reachable_nonterminals(g)
    dead = empty_terminals(g)
    spell = spellable_terminals(g, tc)
    vocab_bytes = {b for tokdata in vocab if tokdata for b in tokdata}
    out: List[Issue] = []
    for tid in sorted(used - dead):
        if tid in g.ignore or tid in spell:
            continue
        dfa = g.terminals[tid].dfa
        missing = sorted(b for b in dfa.first_bytes(dfa.start)
                         if b not in vocab_bytes)
        hint = (f"; e.g. no token contains the start byte(s) "
                f"{[chr(b) if 32 <= b < 127 else hex(b) for b in missing[:8]]}"
                if missing else "")
        out.append(Issue(
            "alignment-gap", "error", g.terminal_name(tid),
            f"pattern {g.terminals[tid].pattern!r} cannot be spelled by "
            f"any token sequence of this vocabulary — productions "
            f"requiring it are unreachable at decode time{hint}"))
    return out


@dataclasses.dataclass
class Exploration:
    """Raw layer-2 BFS result (pre-report)."""
    finite: bool
    n_states: int
    n_edges: int
    eos_ok: Dict[int, bool]
    empty_mask: Dict[int, bool]
    paths: Dict[int, List[int]]
    rev_edges: Dict[int, Set[int]]
    max_fanout: int
    n_merge_checks: int
    n_mask_conflicts: int
    # edges whose advance() overflowed MAX_HYPOTHESES and truncated the
    # hypothesis set: runtime masks beyond such an edge may be UNSOUND
    # (legal tokens silently excluded)
    n_hyp_truncations: int
    # forward transition structure (the device-table feedstock):
    # edges[sid][tok] = successor state id for every explored
    # (mask-legal, non-EOS) edge; masks[sid] = the representative's
    # packed mask row (a reference to the memoized read-only array);
    # key_ids = abstract key -> state id
    edges: Dict[int, Dict[int, int]] = dataclasses.field(
        default_factory=dict)
    masks: Dict[int, np.ndarray] = dataclasses.field(default_factory=dict)
    key_ids: Dict[Tuple, int] = dataclasses.field(default_factory=dict)


def explore_decoder(g: Grammar, vocab: Sequence[Optional[bytes]],
                    eos_id: int, tree_cache: Optional[TreeCache] = None,
                    clamp: int = DEFAULT_CLAMP,
                    max_states: int = DEFAULT_MAX_STATES) -> Exploration:
    """Exhaustive BFS over the abstract decoder state space.

    Each abstract key keeps its FIRST concrete decoder as representative;
    masks/transitions are computed on representatives via the real packed
    bitset walk, so witnesses are concrete by construction.  BFS order
    makes every recorded path a shortest witness (in tokens).
    """
    v = len(vocab)
    root = DominoDecoder(g, list(vocab), eos_id, tree_cache=tree_cache)
    ids: Dict[Tuple, int] = {root.abstract_key(clamp): 0}
    reps: Dict[int, DominoDecoder] = {0: root}
    paths: Dict[int, List[int]] = {0: []}
    eos_ok: Dict[int, bool] = {}
    empty_mask: Dict[int, bool] = {}
    rev: Dict[int, Set[int]] = collections.defaultdict(set)
    fwd: Dict[int, Dict[int, int]] = collections.defaultdict(dict)
    masks: Dict[int, np.ndarray] = {}
    queue = collections.deque([0])
    finite = True
    n_edges = 0
    max_fanout = 1
    n_checks = 0
    n_conflicts = 0
    n_merges = 0
    n_truncs = 0
    with warnings.catch_warnings():
        # truncation warns once per decoder; the BFS clones thousands of
        # decoders, so the per-request warning becomes spam here — the
        # count is surfaced in the report instead
        warnings.simplefilter("ignore", RuntimeWarning)
        while queue:
            sid = queue.popleft()
            d = reps[sid]
            max_fanout = max(max_fanout, len(d.hyps))
            bits = d.mask_bits()
            masks[sid] = bits          # shared read-only memo reference
            eos_ok[sid] = bitmask.get_bit(bits, eos_id)
            legal = bitmask.to_ids(bits, v)
            empty_mask[sid] = legal.size == 0
            for tok in legal:
                tok = int(tok)
                if tok == eos_id:
                    continue         # edge into the absorbing final state
                d2 = d.clone()
                if not d2.advance(tok):
                    # mask bit set but advance refused: decoder-internal
                    # mask/transition disagreement — count, never hide
                    n_conflicts += 1
                    continue
                if d2.n_hyp_truncations > d.n_hyp_truncations:
                    n_truncs += 1
                key2 = d2.abstract_key(clamp)
                tid = ids.get(key2)
                if tid is None:
                    if len(ids) >= max_states:
                        finite = False
                        continue         # frontier clipped by the bound
                    tid = len(ids)
                    ids[key2] = tid
                    reps[tid] = d2
                    paths[tid] = paths[sid] + [tok]
                    queue.append(tid)
                else:
                    n_merges += 1
                    if n_merges % MERGE_CHECK_STRIDE == 0:
                        # quotient-soundness sampling: the arriving
                        # concrete state must agree with the
                        # representative's mask
                        n_checks += 1
                        if not np.array_equal(d2.mask_bits(),
                                              reps[tid].mask_bits()):
                            n_conflicts += 1
                rev[tid].add(sid)
                fwd[sid][tok] = tid
                n_edges += 1
    return Exploration(finite=finite, n_states=len(ids), n_edges=n_edges,
                       eos_ok=eos_ok, empty_mask=empty_mask, paths=paths,
                       rev_edges=dict(rev), max_fanout=max_fanout,
                       n_merge_checks=n_checks,
                       n_mask_conflicts=n_conflicts,
                       n_hyp_truncations=n_truncs,
                       edges=dict(fwd), masks=masks, key_ids=dict(ids))


def build_device_table(ex: Exploration, v: int, eos_id: int,
                       clamp: int) -> Optional[DeviceGrammarTable]:
    """Assemble the :class:`DeviceGrammarTable` from an exploration —
    or refuse (return None) unless the closure certificate is CLEAN:

     - ``finite`` — the explored graph is the whole reachable quotient
       (a clipped frontier would make OFF_FRONTIER lie);
     - zero mask conflicts — no explored merge arrived with a mask
       different from its representative's;
     - zero hypothesis truncations — no explored edge overflowed
       MAX_HYPOTHESES, so no mask in the table is potentially unsound;
     - zero trap states — the fused loop's masked argmax always has at
       least one legal token to pick (dead ends would otherwise need
       in-loop detection that the host path handles explicitly).

    Every non-EOS token a table mask allows has a recorded transition,
    so a table walk can only stop at EOS, budget, or an OFF_FRONTIER
    edge — which never appears under a clean certificate.

    SCOPE OF THE CERTIFICATE: the key quotient is an *abstraction* — the
    clamped relative signature deliberately folds state a context-free
    grammar can keep unbounded (e.g. JSON's bracket-nesting stack), so a
    finite table cannot be exact in general.  "Zero mask conflicts"
    certifies every merge the BFS *observed*, not bisimilarity: a
    concrete trajectory can eventually reach a state whose mask differs
    from its table row (a QUOTIENT ESCAPE).  Consumers must therefore
    (a) validate every table-selected token against the concrete checker
    (``advance`` returning False is a certificate violation, never to be
    committed silently), and (b) periodically audit the table mask row
    against the concrete mask, demoting escaped rows to the exact host
    path — the serving scheduler does both, bounding any divergence from
    the host path to one audit interval while output stays
    grammar-valid unconditionally."""
    clean = (ex.finite and ex.n_mask_conflicts == 0
             and ex.n_hyp_truncations == 0
             and not any(ex.empty_mask.values()))
    if not clean or not ex.masks:
        return None
    w = bitmask.n_words(v)
    mask_table = np.zeros((ex.n_states, w), np.uint32)
    trans = np.full((ex.n_states, v), OFF_FRONTIER, np.int32)
    for sid in range(ex.n_states):
        mask_table[sid] = ex.masks[sid]
        for tok, tid in ex.edges.get(sid, {}).items():
            trans[sid, tok] = tid
    return DeviceGrammarTable(n_states=ex.n_states, v=v, eos_id=eos_id,
                              clamp=clamp, mask_table=mask_table,
                              trans=trans, key_to_sid=dict(ex.key_ids))


def _replay_trap(g: Grammar, vocab: Sequence[Optional[bytes]], eos_id: int,
                 tokens: List[int],
                 tree_cache: Optional[TreeCache]) -> bool:
    """Replay a witness path through a FRESH decoder: True iff every
    advance succeeds and the final state is a runtime dead end (empty
    mask, EOS illegal) — i.e. the abstract trap is concretely real."""
    d = DominoDecoder(g, list(vocab), eos_id, tree_cache=tree_cache)
    for t in tokens:
        if not d.advance(t):
            return False
    bits = d.mask_bits()
    return not bits.any()


def _witness_text(vocab: Sequence[Optional[bytes]],
                  tokens: List[int]) -> bytes:
    return b"".join(vocab[t] or b"" for t in tokens)


def analyze(g: Grammar, vocab: Sequence[Optional[bytes]], eos_id: int,
            name: str = "<anonymous>",
            tree_cache: Optional[TreeCache] = None,
            clamp: int = DEFAULT_CLAMP,
            max_states: int = DEFAULT_MAX_STATES,
            max_witnesses: int = 16,
            emit_device_table: bool = False) -> AnalysisReport:
    """Run both analysis layers and assemble the :class:`AnalysisReport`.

    ``tree_cache`` should be the grammar's registry-shared cache when
    called from the engine, so the trees built here are the SAME trees
    serving later uses (the analysis doubles as the precompute warm-up).
    ``max_witnesses`` caps how many trap / non-live witnesses are
    materialized (the counts are always exact).
    ``emit_device_table`` additionally assembles the
    :class:`DeviceGrammarTable` from the exploration (clean certificates
    only — see :func:`build_device_table`); it is opt-in because the
    dense ``(n_states, V)`` transition table costs ``n_states * V * 4``
    bytes of host memory that pure diagnostics never need.
    """
    t0 = time.perf_counter()
    issues = analyze_static(g)
    tc = tree_cache if tree_cache is not None else TreeCache(
        Scanner(g), list(vocab))
    gaps = alignment_gap_issues(g, tc, vocab)
    ex = explore_decoder(g, vocab, eos_id, tree_cache=tc, clamp=clamp,
                         max_states=max_states)

    traps: List[Witness] = []
    trap_ids = [sid for sid in sorted(ex.empty_mask)
                if ex.empty_mask[sid]]
    for sid in trap_ids[:max_witnesses]:
        path = ex.paths[sid]
        traps.append(Witness(
            state_id=sid, token_ids=path,
            text=_witness_text(vocab, path),
            confirmed=_replay_trap(g, vocab, eos_id, path, tc)))

    non_live: List[Witness] = []
    if ex.finite:
        # reverse reachability from every EOS-legal state; anything
        # outside is a liveness hole.  Traps are reported above, not
        # double-reported here.
        live = {sid for sid, ok in ex.eos_ok.items() if ok}
        stack = list(live)
        while stack:
            sid = stack.pop()
            for prev in ex.rev_edges.get(sid, ()):
                if prev not in live:
                    live.add(prev)
                    stack.append(prev)
        hole_ids = [sid for sid in sorted(ex.eos_ok)
                    if sid not in live and not ex.empty_mask[sid]]
        for sid in hole_ids[:max_witnesses]:
            path = ex.paths[sid]
            non_live.append(Witness(
                state_id=sid, token_ids=path,
                text=_witness_text(vocab, path),
                # replay confirms reachability of the state, not the
                # (graph-global) liveness claim itself
                confirmed=True))

    words = bitmask.n_words(len(vocab))
    cert = ClosureCertificate(
        finite=ex.finite, n_states=ex.n_states, n_edges=ex.n_edges,
        mask_words=words, table_words=ex.n_states * words,
        table_bytes=ex.n_states * words * 4, clamp=clamp,
        max_states=max_states)
    return AnalysisReport(
        grammar_name=name, vocab_size=len(vocab), eos_id=eos_id,
        n_terminals=g.n_terminals, n_nonterminals=g.n_nonterminals,
        n_rules=len(g.rules), issues=issues, alignment_gaps=gaps,
        traps=traps, non_eos_live=non_live, closure=cert,
        max_abstract_fanout=ex.max_fanout,
        n_merge_checks=ex.n_merge_checks,
        n_mask_conflicts=ex.n_mask_conflicts,
        n_hyp_truncations=ex.n_hyp_truncations,
        device_table=(build_device_table(ex, len(vocab), eos_id, clamp)
                      if emit_device_table else None),
        analysis_time_s=time.perf_counter() - t0)
