"""Model-based retokenization (Algorithm 3, App. B).

Re-encode a target byte string with the tokenization the model itself would
have chosen when forced to produce exactly that text: at each step, among
all vocabulary tokens that are a prefix of the remaining target, pick the
one with the highest model logit.  Used to *naturalize* template-generated
output for the invasiveness analysis (Fig. 2), and as a utility to turn
few-shot demonstration text into model-preferred token ids.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.trees import VocabTrie


def prefix_tokens(trie: VocabTrie, target: bytes) -> List[int]:
    """All token ids that are a (non-empty) prefix of ``target``."""
    out: List[int] = []
    node = trie
    for b in target:
        node = node.children.get(b)
        if node is None:
            break
        out.extend(node.token_ids)
    return out


def retokenize(model_logits: Callable[[List[int]], np.ndarray],
               prompt_ids: List[int], target: bytes,
               vocab: Sequence[Optional[bytes]],
               trie: Optional[VocabTrie] = None) -> List[int]:
    """Algorithm 3: greedy model-preferred tokenization of ``target``.

    ``model_logits(ids)`` returns next-token logits after ``ids``.
    """
    trie = trie or VocabTrie.build(list(vocab))
    out: List[int] = []
    rest = target
    while rest:
        cands = prefix_tokens(trie, rest)
        if not cands:
            raise ValueError(
                f"no vocabulary token is a prefix of {rest[:20]!r}; "
                "vocabulary must cover all single bytes of the target")
        logits = model_logits(prompt_ids + out)
        best = max(cands, key=lambda t: logits[t])
        out.append(best)
        rest = rest[len(vocab[best]):]
    return out


def greedy_tokenize(target: bytes, vocab: Sequence[Optional[bytes]],
                    trie: Optional[VocabTrie] = None) -> List[int]:
    """External-tokenizer stand-in: longest-match greedy encoding (the kind
    of fixed tokenization that causes template-induced misalignment)."""
    trie = trie or VocabTrie.build(list(vocab))
    out: List[int] = []
    rest = target
    while rest:
        cands = prefix_tokens(trie, rest)
        if not cands:
            raise ValueError(f"untokenizable byte {rest[:1]!r}")
        best = max(cands, key=lambda t: len(vocab[t]))
        out.append(best)
        rest = rest[len(vocab[best]):]
    return out
