"""Character scanner: the union automaton of all terminal regexes (§3.2).

Lemma 3.1: any legal program of CFG ``G`` is a sequence of terminals, so the
regex ``R = (r_1 | ... | r_n)+`` over-approximates ``L_G``.  The scanner
tracks *which* terminal sub-automaton each active state belongs to, so that
feeding a vocabulary token byte-by-byte enumerates the *subterminal
sequences* (§3.3) the token induces:

 - ``emissions`` — the terminals completed inside the token (END/FULL
   subterminals, reported to the parser), and
 - ``final position`` — either the FRESH boundary (token ends exactly at a
   terminal boundary) or a mid-terminal position (START/CONTINUATION
   subterminal), represented as a frozenset of ``(terminal_id, dfa_state)``
   configurations (a set because of lexical ambiguity, e.g. keyword vs
   identifier).

Each terminal regex is compiled to its own *byte DFA* (dead states pruned,
so every configuration is live = can still reach acceptance).  The
nondeterminism of the union NFA lives in the *set* of configurations and in
the emit-vs-continue branch at accepting states (maximal munch is NOT
imposed: both segmentations are kept, and the parser prunes illegal ones —
this is required for minimal invasiveness).
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Optional, Tuple

from repro.core.grammar import Grammar

# A scanner position: FRESH (token boundary) or frozenset[(tid, dfa_state)].
FRESH = "FRESH"
Position = object  # FRESH | FrozenSet[Tuple[int, int]]
Branch = Tuple[Tuple[int, ...], object]  # (emissions, final_position)


class Scanner:
    def __init__(self, grammar: Grammar):
        self.g = grammar
        self.dfas = [t.dfa for t in grammar.terminals]
        self.ignore = frozenset(grammar.ignore)
        # start moves: byte -> frozenset of (tid, state) configurations
        self._start_moves: Dict[int, FrozenSet[Tuple[int, int]]] = {}
        for b in range(256):
            confs = []
            for tid, dfa in enumerate(self.dfas):
                s2 = dfa.step(dfa.start, b)
                if s2 is not None:
                    confs.append((tid, s2))
            if confs:
                self._start_moves[b] = frozenset(confs)

    # -- single-byte relation -----------------------------------------------

    def start_moves(self, byte: int) -> Optional[FrozenSet[Tuple[int, int]]]:
        return self._start_moves.get(byte)

    def accepting_terminals(self, position) -> List[Tuple[int, int]]:
        """Configurations of ``position`` at an accepting DFA state."""
        if position is FRESH:
            return []
        return [(t, s) for (t, s) in position if self.dfas[t].is_accept(s)]

    # -- token traversal -----------------------------------------------------

    def traverse_token(self, position, token_bytes: bytes,
                       collapse_ignore: bool = True) -> List[Branch]:
        """Enumerate all (emissions, final_position) branches for feeding
        ``token_bytes`` starting at ``position``.

        ``collapse_ignore=True`` drops ignorable terminals (e.g. whitespace)
        from the emission sequences — the parser never sees them, so
        branches differing only in ignore-runs are merged.
        """
        if position is FRESH:
            init: FrozenSet[Tuple[int, int]] = frozenset()
            branches: Dict[Tuple[int, ...], set] = {(): {("FRESH",)}}
            # We encode "at fresh boundary" as the pseudo-conf ("FRESH",).
        else:
            branches = {(): set(position)}
        for b in token_bytes:
            new_branches: Dict[Tuple[int, ...], set] = {}
            starts = self._start_moves.get(b)
            for ems, confs in branches.items():
                direct = set()
                emit_terminals = set()
                for conf in confs:
                    if conf == ("FRESH",):
                        if starts:
                            direct.update(starts)
                        continue
                    t, s = conf
                    dfa = self.dfas[t]
                    s2 = dfa.step(s, b)
                    if s2 is not None:
                        direct.add((t, s2))
                    if dfa.is_accept(s):
                        emit_terminals.add(t)
                if direct:
                    new_branches.setdefault(ems, set()).update(direct)
                if starts:
                    for t in emit_terminals:
                        if collapse_ignore and t in self.ignore:
                            key = ems
                        else:
                            key = ems + (t,)
                        new_branches.setdefault(key, set()).update(starts)
            branches = new_branches
            if not branches:
                return []
        out: List[Branch] = []
        seen = set()
        for ems, confs in branches.items():
            real = frozenset(c for c in confs if c != ("FRESH",))
            if real:
                out.append((ems, real))
            if ("FRESH",) in confs and (ems, FRESH) not in seen:
                seen.add((ems, FRESH))
                out.append((ems, FRESH))
            # Emit-at-token-end: a configuration sitting exactly on an
            # accepting state may close its terminal at the boundary.
            for (t, s) in real:
                if self.dfas[t].is_accept(s):
                    key = ems if (collapse_ignore and t in self.ignore) \
                        else ems + (t,)
                    if (key, FRESH) not in seen:
                        seen.add((key, FRESH))
                        out.append((key, FRESH))
        return out

    def final_branches(self, position) -> List[Tuple[Tuple[int, ...], bool]]:
        """Branches available when generation stops at ``position``:
        (emissions, clean) where clean=True means the position closes at a
        terminal boundary.  Used for EOS legality."""
        if position is FRESH:
            return [((), True)]
        out = []
        for (t, s) in position:
            if self.dfas[t].is_accept(s):
                if t in self.ignore:
                    out.append(((), True))
                else:
                    out.append(((t,), True))
        return out
