"""Baseline constrained-decoding methods the paper compares against (§2, §4).

1. **Naive greedy constraining** (Fig. 1): only tokens that lie entirely
   within a single grammar terminal are allowed — no bridge tokens.  In our
   lookahead formulation this is exactly ``DOMINO(k=0)`` (the paper's Table 4
   ``k=0`` row equals the §2 naive accuracy number), so we expose it as a
   thin wrapper.

2. **Online parser-guided checking** (llama.cpp grammars / GCD /
   SYNCHROMESH): semantically identical to DOMINO(k=∞) but withOUT
   precomputation — every decode step scans the *entire vocabulary* and
   feeds each token through scanner+parser.  This is the throughput
   baseline of Table 3.

3. **Template-based generation** (GUIDANCE / LMQL): fixed text chunks are
   tokenized externally and force-inserted; only slot contents are
   generated under regex constraints.  Fast (skips forward passes for
   fixed tokens) but invasive: the external tokenization induces the
   misalignment of Fig. 2.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import regex as rx
from repro.core.domino import DominoDecoder
from repro.core.grammar import Grammar
from repro.core.scanner import FRESH


def naive_greedy_decoder(grammar: Grammar, vocab, eos_id: int,
                         tree_cache=None) -> DominoDecoder:
    """Fig.-1 style greedy constraining == DOMINO with lookahead k=0."""
    return DominoDecoder(grammar, vocab, eos_id, k=0, tree_cache=tree_cache)


class OnlineParserDecoder(DominoDecoder):
    """Full-vocabulary online checking: no subterminal trees.

    mask() costs O(|V| * token_len * hypotheses) parser/scanner work per
    step — the cost profile of llama.cpp grammars and GCD, used as the
    performance baseline.  Produces bit-identical masks to DOMINO(k=∞).
    """

    def __init__(self, grammar: Grammar, vocab, eos_id: int, **kw):
        kw.pop("k", None)
        super().__init__(grammar, vocab, eos_id, k=None, **kw)

    def mask(self, k=None) -> np.ndarray:
        out = np.zeros(len(self.vocab), dtype=bool)
        if self.finished:
            return out
        for tok_id, data in enumerate(self.vocab):
            if tok_id == self.eos_id or data is None or len(data) == 0:
                continue
            if self._advance_hyps(tok_id, dry_run=True):
                out[tok_id] = True
        if self.eos_legal():
            out[self.eos_id] = True
        return out

    def mask_bits(self, k=None) -> np.ndarray:
        """Pack the online-scanned mask.  No tree segments and no memo —
        re-checking the whole vocabulary every step IS the baseline cost
        profile this class exists to measure."""
        from repro.core import bitmask
        return bitmask.pack_bool(self.mask(k))


# ---------------------------------------------------------------------------
# Template-based (GUIDANCE-style)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Fixed:
    """A templated chunk, force-inserted via external tokenization."""
    text: str


@dataclasses.dataclass
class Gen:
    """A generation slot constrained by a regex, ended by ``stop`` text or
    by regex completion (whichever the model reaches first)."""
    pattern: str
    stop: Optional[str] = None
    max_tokens: int = 64


TemplatePart = Union[Fixed, Gen]


class TemplateSession:
    """Executes a GUIDANCE-style template against a token-level model.

    The engine asks ``next_action()`` what to do:
      ("force", [token_ids])  — append fixed tokens without a forward pass
      ("gen", mask)           — run the model, sample under ``mask``
      ("done", None)
    and reports sampled tokens back via ``feed(token_id)``.
    """

    def __init__(self, parts: Sequence[TemplatePart],
                 vocab: Sequence[Optional[bytes]], eos_id: int,
                 encode: Callable[[str], List[int]]):
        self.parts = list(parts)
        self.vocab = vocab
        self.eos_id = eos_id
        self.encode = encode
        self.part_idx = 0
        self._slot_dfa: Optional[rx.DFA] = None
        self._slot_state: Optional[int] = None
        self._slot_bytes = b""
        self._slot_tokens = 0
        self.forced_tokens = 0
        self.generated_tokens = 0

    def _enter_part(self):
        while self.part_idx < len(self.parts):
            part = self.parts[self.part_idx]
            if isinstance(part, Fixed):
                ids = self.encode(part.text)
                self.part_idx += 1
                self.forced_tokens += len(ids)
                return ("force", ids)
            # Gen slot
            if self._slot_dfa is None:
                self._slot_dfa = rx.compile_pattern(part.pattern)
                self._slot_state = self._slot_dfa.start
                self._slot_bytes = b""
                self._slot_tokens = 0
            return ("gen", self._slot_mask(part))
        return ("done", None)

    def next_action(self):
        return self._enter_part()

    def _token_fits(self, data: bytes, part: Gen) -> bool:
        st = self._slot_state
        dfa = self._slot_dfa
        for b in data:
            st = dfa.step(st, b)
            if st is None:
                return False
        return True

    def _slot_mask(self, part: Gen) -> np.ndarray:
        mask = np.zeros(len(self.vocab), dtype=bool)
        # token budget exhausted: force the slot closed
        if self._slot_tokens >= part.max_tokens and \
                self._slot_dfa.is_accept(self._slot_state):
            mask[self.eos_id] = True
            return mask
        for tok_id, data in enumerate(self.vocab):
            if data is None or len(data) == 0:
                continue
            if self._token_fits(data, part):
                mask[tok_id] = True
        # allow ending the slot when the regex currently accepts
        if self._slot_dfa.is_accept(self._slot_state):
            mask[self.eos_id] = True
        return mask

    def feed(self, token_id: int) -> None:
        part = self.parts[self.part_idx]
        assert isinstance(part, Gen)
        self.generated_tokens += 1
        if token_id == self.eos_id:
            self._finish_slot()
            return
        data = self.vocab[token_id]
        for b in data:
            self._slot_state = self._slot_dfa.step(self._slot_state, b)
        self._slot_bytes += data
        self._slot_tokens += 1
        if part.stop is not None and part.stop.encode() in self._slot_bytes:
            self._finish_slot()
        elif (self._slot_dfa.is_accept(self._slot_state)
              and not self._slot_dfa.can_continue(self._slot_state)):
            self._finish_slot()

    def _finish_slot(self):
        self._slot_dfa = None
        self._slot_state = None
        self.part_idx += 1


# ---------------------------------------------------------------------------
# Regex-only constraining (Outlines-style precomputed DFA-token table)
# ---------------------------------------------------------------------------


class RegexDecoder:
    """Willard & Louf (2023): precompute, for every DFA state, the set of
    vocabulary tokens that keep the DFA alive.  Regular expressions only —
    the expressivity rung below DOMINO's CFGs."""

    def __init__(self, pattern: str, vocab: Sequence[Optional[bytes]],
                 eos_id: int):
        self.dfa = rx.compile_pattern(pattern)
        self.vocab = vocab
        self.eos_id = eos_id
        self.state: Optional[int] = self.dfa.start
        self.finished = False
        # Precompute state -> allowed token ids (the Outlines index).
        self.table: List[np.ndarray] = []
        for st in range(self.dfa.n_states):
            ok = []
            for tok_id, data in enumerate(vocab):
                if data is None or len(data) == 0:
                    continue
                s = st
                alive = True
                for b in data:
                    s = self.dfa.step(s, b)
                    if s is None:
                        alive = False
                        break
                if alive:
                    ok.append(tok_id)
            self.table.append(np.asarray(ok, dtype=np.int32))

    def mask(self) -> np.ndarray:
        out = np.zeros(len(self.vocab), dtype=bool)
        if self.finished:
            return out
        out[self.table[self.state]] = True
        if self.dfa.is_accept(self.state):
            out[self.eos_id] = True
        return out

    def advance(self, token_id: int) -> bool:
        if token_id == self.eos_id:
            if self.dfa.is_accept(self.state):
                self.finished = True
                return True
            return False
        s = self.state
        for b in self.vocab[token_id]:
            s = self.dfa.step(s, b)
            if s is None:
                return False
        self.state = s
        return True
