"""Context-free grammar representation + EBNF parser.

A grammar is a set of BNF productions over *terminals* (defined by regex or
literal — compiled to byte DFAs via :mod:`repro.core.regex`) and
*nonterminals*.  This is the ``G`` of DOMINO §3.1: the parser enforces the
productions, the scanner (see :mod:`repro.core.scanner`) enforces terminal
regexes, per Lemma 3.1.

Text format (Lark-like):

    // line comment  (or '#')
    start: value
    value: object | array | STRING | NUMBER
    object: "{" (pair ("," pair)*)? "}"
    pair: STRING ":" value
    STRING: /"([^"\\]|\\.)*"/
    NUMBER: /-?[0-9]+/
    WS: /[ \t\n\r]+/
    %ignore WS

 - lowercase names: nonterminals; UPPERCASE names: terminals.
 - ``"..."`` inside rules: anonymous literal terminals (deduplicated).
 - EBNF sugar ``( ) | * + ?`` is lowered to fresh BNF rules.
 - ``%ignore T`` marks terminal T as skippable anywhere (lexer-level).

Symbols are encoded as ints: ``sym >= 0`` is a terminal id, ``sym < 0`` is
nonterminal ``~sym``.
"""
from __future__ import annotations

import dataclasses
import re as _stdre
from typing import Dict, List, Optional, Tuple

from repro.core import regex as rx


def nt(nid: int) -> int:
    """Encode nonterminal id as a symbol."""
    return ~nid


def is_terminal(sym: int) -> bool:
    return sym >= 0


def nt_id(sym: int) -> int:
    return ~sym


@dataclasses.dataclass
class Terminal:
    name: str
    dfa: rx.DFA
    pattern: str          # source pattern (regex or literal), for display
    is_literal: bool


@dataclasses.dataclass
class Rule:
    lhs: int              # nonterminal id
    rhs: Tuple[int, ...]  # encoded symbols


class Grammar:
    def __init__(self, terminals: List[Terminal], rules: List[Rule],
                 nonterminal_names: List[str], start: int,
                 ignore: Tuple[int, ...] = ()):
        self.terminals = terminals
        self.rules = rules
        self.nonterminal_names = nonterminal_names
        self.start = start                     # nonterminal id
        self.ignore = tuple(ignore)            # terminal ids skippable anywhere
        # index: rules by lhs
        self.rules_by_lhs: Dict[int, List[int]] = {}
        for i, r in enumerate(rules):
            self.rules_by_lhs.setdefault(r.lhs, []).append(i)
        self.nullable = self._compute_nullable()

    @property
    def n_terminals(self) -> int:
        return len(self.terminals)

    @property
    def n_nonterminals(self) -> int:
        return len(self.nonterminal_names)

    def _compute_nullable(self) -> frozenset:
        nullable: set = set()
        changed = True
        while changed:
            changed = False
            for r in self.rules:
                if r.lhs in nullable:
                    continue
                if all((not is_terminal(s)) and nt_id(s) in nullable
                       for s in r.rhs):
                    nullable.add(r.lhs)
                    changed = True
        return frozenset(nullable)

    def first_sets(self) -> Dict[int, frozenset]:
        """FIRST sets: nonterminal id -> terminal ids that can begin one
        of its derivations.  Standard fixpoint over the rules, epsilon
        handled through ``nullable``.  Used by the static analyzer
        (:mod:`repro.core.analysis`) and useful for any table-driven
        consumer of the grammar."""
        first: Dict[int, set] = {n: set()
                                 for n in range(len(self.nonterminal_names))}
        changed = True
        while changed:
            changed = False
            for r in self.rules:
                f = first[r.lhs]
                before = len(f)
                for s in r.rhs:
                    if is_terminal(s):
                        f.add(s)
                        break
                    f |= first[nt_id(s)]
                    if nt_id(s) not in self.nullable:
                        break
                if len(f) != before:
                    changed = True
        return {n: frozenset(v) for n, v in first.items()}

    def terminal_name(self, tid: int) -> str:
        return self.terminals[tid].name

    def describe(self) -> str:
        lines = []
        for r in self.rules:
            rhs = " ".join(
                self.terminals[s].name if is_terminal(s)
                else self.nonterminal_names[nt_id(s)]
                for s in r.rhs) or "ε"
            lines.append(f"{self.nonterminal_names[r.lhs]} -> {rhs}")
        return "\n".join(lines)


class GrammarSyntaxError(ValueError):
    pass


# ---------------------------------------------------------------------------
# EBNF text parser
# ---------------------------------------------------------------------------

_TOKEN_RE = _stdre.compile(
    r"""
    (?P<ws>\s+)
  | (?P<comment>(//|\#)[^\n]*)
  | (?P<name>[A-Za-z_][A-Za-z_0-9]*)
  | (?P<string>"(\\.|[^"\\])*")
  | (?P<regex>/(\\.|[^/\\])+/)
  | (?P<op>[:|()*+?])
  | (?P<directive>%[a-z]+)
    """,
    _stdre.VERBOSE,
)


def _lex(text: str):
    pos = 0
    out = []
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if not m:
            raise GrammarSyntaxError(f"bad grammar syntax at {text[pos:pos+30]!r}")
        pos = m.end()
        kind = m.lastgroup
        if kind in ("ws", "comment"):
            continue
        out.append((kind, m.group()))
    out.append(("eof", ""))
    return out


@dataclasses.dataclass(frozen=True)
class _Sym:
    kind: str  # 'name' | 'literal' | 'regex'
    value: str


@dataclasses.dataclass(frozen=True)
class _Seq:
    items: tuple


@dataclasses.dataclass(frozen=True)
class _Alts:
    options: tuple


@dataclasses.dataclass(frozen=True)
class _Rep:
    inner: object
    op: str  # '*' '+' '?'


class _EbnfParser:
    def __init__(self, tokens):
        self.toks = tokens
        self.i = 0

    def peek(self):
        return self.toks[self.i]

    def next(self):
        t = self.toks[self.i]
        self.i += 1
        return t

    def expect(self, kind, val=None):
        k, v = self.next()
        if k != kind or (val is not None and v != val):
            raise GrammarSyntaxError(f"expected {kind} {val}, got {k} {v!r}")
        return v

    def parse_alts(self) -> _Alts:
        opts = [self.parse_seq()]
        while self.peek() == ("op", "|"):
            self.next()
            opts.append(self.parse_seq())
        return _Alts(tuple(opts))

    def parse_seq(self) -> _Seq:
        items = []
        while True:
            k, v = self.peek()
            if k == "name" and self.toks[self.i + 1] == ("op", ":"):
                break  # start of next rule
            if k in ("eof", "directive") or (k == "op" and v in "|)"):
                break
            items.append(self.parse_item())
        return _Seq(tuple(items))

    def parse_item(self):
        node = self.parse_atom()
        while self.peek()[0] == "op" and self.peek()[1] in "*+?":
            _, op = self.next()
            node = _Rep(node, op)
        return node

    def parse_atom(self):
        k, v = self.next()
        if k == "name":
            return _Sym("name", v)
        if k == "string":
            return _Sym("literal", _unescape(v[1:-1]))
        if k == "regex":
            return _Sym("regex", v[1:-1].replace("\\/", "/"))
        if (k, v) == ("op", "("):
            inner = self.parse_alts()
            self.expect("op", ")")
            return inner
        raise GrammarSyntaxError(f"unexpected {k} {v!r} in rule body")


def _unescape(s: str) -> str:
    out = []
    i = 0
    while i < len(s):
        c = s[i]
        if c == "\\" and i + 1 < len(s):
            nxt = s[i + 1]
            mapping = {"n": "\n", "t": "\t", "r": "\r", '"': '"', "\\": "\\",
                       "/": "/", "0": "\0"}
            out.append(mapping.get(nxt, nxt))
            i += 2
        else:
            out.append(c)
            i += 1
    return "".join(out)


class _Builder:
    def __init__(self):
        self.terminals: List[Terminal] = []
        self.term_index: Dict[Tuple[str, str], int] = {}  # (kind,key)->tid
        self.nt_names: List[str] = []
        self.nt_index: Dict[str, int] = {}
        self.rules: List[Rule] = []
        self._anon = 0

    def get_nt(self, name: str) -> int:
        if name not in self.nt_index:
            self.nt_index[name] = len(self.nt_names)
            self.nt_names.append(name)
        return self.nt_index[name]

    def fresh_nt(self, hint: str) -> int:
        self._anon += 1
        return self.get_nt(f"__{hint}_{self._anon}")

    def get_literal_terminal(self, text: str) -> int:
        key = ("lit", text)
        if key not in self.term_index:
            self.term_index[key] = len(self.terminals)
            self.terminals.append(
                Terminal(name=repr(text), dfa=rx.literal_dfa(text),
                         pattern=text, is_literal=True))
        return self.term_index[key]

    def def_terminal(self, name: str, kind: str, pattern: str) -> int:
        key = ("name", name)
        if key in self.term_index:
            raise GrammarSyntaxError(f"terminal {name} redefined")
        tid = len(self.terminals)
        self.term_index[key] = tid
        if kind == "literal":
            dfa = rx.literal_dfa(pattern)
        else:
            dfa = rx.compile_pattern(pattern)
            if dfa.matches(b""):
                raise GrammarSyntaxError(
                    f"terminal {name} matches the empty string; "
                    "empty terminals are not supported (make it '+' not '*')")
        self.terminals.append(Terminal(name=name, dfa=dfa, pattern=pattern,
                                       is_literal=(kind == "literal")))
        return tid

    def lookup_terminal(self, name: str) -> Optional[int]:
        return self.term_index.get(("name", name))

    # -- EBNF lowering ------------------------------------------------------
    def lower(self, lhs: int, node) -> None:
        if isinstance(node, _Alts):
            for opt in node.options:
                self.rules.append(Rule(lhs, self.lower_seq(opt)))
        else:
            self.rules.append(Rule(lhs, self.lower_seq(node)))

    def lower_seq(self, seq: _Seq) -> Tuple[int, ...]:
        syms = []
        for item in seq.items:
            syms.append(self.lower_item(item))
        return tuple(syms)

    def lower_item(self, item) -> int:
        if isinstance(item, _Sym):
            if item.kind == "literal":
                return self.get_literal_terminal(item.value)
            if item.kind == "regex":
                # anonymous inline regex terminal
                key = ("rx", item.value)
                if key not in self.term_index:
                    self.term_index[key] = len(self.terminals)
                    self.terminals.append(Terminal(
                        name=f"/{item.value}/",
                        dfa=rx.compile_pattern(item.value),
                        pattern=item.value, is_literal=False))
                return self.term_index[key]
            name = item.value
            if name[0].isupper():
                tid = self.lookup_terminal(name)
                if tid is None:
                    raise GrammarSyntaxError(f"undefined terminal {name}")
                return tid
            return nt(self.get_nt(name))
        if isinstance(item, _Alts):
            fresh = self.fresh_nt("grp")
            self.lower(fresh, item)
            return nt(fresh)
        if isinstance(item, _Rep):
            inner_sym = self.lower_item(item.inner)
            fresh = self.fresh_nt("rep")
            if item.op == "?":
                self.rules.append(Rule(fresh, ()))
                self.rules.append(Rule(fresh, (inner_sym,)))
            elif item.op == "*":
                self.rules.append(Rule(fresh, ()))
                self.rules.append(Rule(fresh, (inner_sym, nt(fresh))))
            elif item.op == "+":
                self.rules.append(Rule(fresh, (inner_sym,)))
                self.rules.append(Rule(fresh, (inner_sym, nt(fresh))))
            return nt(fresh)
        raise TypeError(item)


def parse_grammar(text: str, start: str = "start") -> Grammar:
    tokens = _lex(text)
    p = _EbnfParser(tokens)
    b = _Builder()
    # First pass: collect rule definitions in order; terminal defs must be
    # processed before rules referencing them, so do two sweeps over the
    # token stream: (1) terminal definitions, (2) nonterminal rules.
    defs: List[Tuple[str, object]] = []
    ignore_names: List[str] = []
    while p.peek()[0] != "eof":
        k, v = p.peek()
        if k == "directive":
            p.next()
            if v == "%ignore":
                nk, nv = p.next()
                if nk != "name":
                    raise GrammarSyntaxError("%ignore expects a terminal name")
                ignore_names.append(nv)
                continue
            raise GrammarSyntaxError(f"unknown directive {v}")
        if k != "name":
            raise GrammarSyntaxError(f"expected rule name, got {k} {v!r}")
        name = p.next()[1]
        p.expect("op", ":")
        body = p.parse_alts()
        defs.append((name, body))
    # Terminal definitions: NAME uppercase and body is a single _Sym literal
    # or regex.
    rule_defs = []
    for name, body in defs:
        if name[0].isupper():
            if (len(body.options) == 1 and len(body.options[0].items) == 1
                    and isinstance(body.options[0].items[0], _Sym)
                    and body.options[0].items[0].kind in ("literal", "regex")):
                sym = body.options[0].items[0]
                b.def_terminal(name, sym.kind, sym.value)
                continue
            raise GrammarSyntaxError(
                f"terminal {name} must be a single literal or /regex/")
        rule_defs.append((name, body))
    if not rule_defs:
        raise GrammarSyntaxError("no rules")
    for name, body in rule_defs:
        b.lower(b.get_nt(name), body)
    if start not in b.nt_index:
        raise GrammarSyntaxError(f"no start rule {start!r}")
    ignore_ids = []
    for n in ignore_names:
        tid = b.lookup_terminal(n)
        if tid is None:
            raise GrammarSyntaxError(f"%ignore of undefined terminal {n}")
        ignore_ids.append(tid)
    return Grammar(b.terminals, b.rules, b.nt_names, b.nt_index[start],
                   tuple(ignore_ids))
